"""Fault injection, end-to-end checksums, graceful degradation (DESIGN.md §16).

The crash-point matrix: a scripted workload (load → flush → compact →
snapshot → migrate) is driven once per instrumented fault site with an
injected failure, then the store is crashed and recovered — the recovered
tree must be prefix-consistent with the acknowledged-op oracle.  Every
corruption mode (WAL tail torn/bitflip/garbage, sampled run block, last
manifest edit) must end **repaired** (WAL truncation to the last good
frame), **quarantined** (typed ``CorruptionError`` + scrub report), or
**survived** (bounded background retry) — never silent.

Also here:
  * CRC-32C known vectors + the vectorized ``crc32c_rows`` == scalar oracle
    property (runs under real hypothesis and the fixed-seed shim);
  * WAL replay terminates at the first checksum-invalid frame even when a
    corrupt length field points past (or inside) the buffer — the
    satellite regression for ``records()`` trusting garbage ``vlen``;
  * background retry/degrade state machine: transient faults retried to a
    tree bit-identical to the sync oracle, persistent faults flip the
    store read-only (reads serve, writes raise ``StoreDegradedError``,
    per-shard in the facade), ``crash()+recover()`` restores service;
  * ``close()`` on a degraded store is idempotent and loss-free;
  * ``recover()`` under telemetry records ``wal_replay``/``scrub``/
    ``corruption`` events and yields a tree bit-equal to a telemetry-off
    twin.
"""
import struct
import zlib

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import (FAULT_SITES, CorruptionError, FaultInjector,
                        InjectedFault, IOStats, LSMConfig, LSMStore,
                        StoreDegradedError, Telemetry, build_run, crc32c,
                        crc32c_rows, make_store)
from repro.core.memtable import _CRC, _HDR, FRAME_OVERHEAD, WriteAheadLog
from repro.core.run import levels_bit_equal

KEY_SPACE = 300


def cfg(**kw):
    base = dict(policy="garnering", T=2.0, c=0.8, memtable_bytes=1 << 12,
                base_level_bytes=1 << 14, bits_per_key=8,
                bloom_allocation="monkey")
    base.update(kw)
    return LSMConfig(**base)


def gen_ops(seed: int, n_ops: int, key_space: int = KEY_SPACE,
            del_frac: float = 0.2):
    rng = np.random.default_rng(seed)
    ops = []
    for i in range(n_ops):
        k = int(rng.integers(0, key_space))
        if rng.random() < del_frac:
            ops.append((k, None))
        else:
            ops.append((k, bytes([65 + i % 26]) * int(rng.integers(0, 100))))
    return ops


def apply_ops(db, ops):
    for k, v in ops:
        (db.delete(k) if v is None else db.put(k, v))


def db_view(db, key_space: int = KEY_SPACE):
    return {k: db.get(k) for k in range(key_space)}


def oracle_view(ops, j, key_space: int = KEY_SPACE):
    d = {k: None for k in range(key_space)}
    for k, v in ops[:j]:
        d[k] = v
    return d


def find_matching_prefix(db, ops, key_space: int = KEY_SPACE):
    """Largest-agnostic prefix search: the index j such that the store's
    readable state equals the oracle after ops[:j], or -1 if no prefix
    matches (i.e. recovery produced a state that never existed)."""
    view = db_view(db, key_space)
    d = {k: None for k in range(key_space)}
    if view == d:
        return 0
    for j, (k, v) in enumerate(ops, start=1):
        d[k] = v
        if view == d:
            return j
    return -1


def _retry(fn, *args):
    """Run fn, retrying through injected faults (the operator's recovery
    action for a transient failure).  Bounded so a miswired everlasting
    fault fails the test instead of hanging it."""
    for _ in range(20):
        try:
            return fn(*args)
        except InjectedFault:
            continue
    raise AssertionError(f"injected fault at {fn.__name__} kept firing")


# ============================================================ CRC-32C oracle

def test_crc32c_known_vectors():
    # the standard CRC-32/ISCSI check value
    assert crc32c(b"123456789") == 0xE3069283
    assert crc32c(b"") == 0
    # and it is NOT zlib's CRC-32/ISO-HDLC — the reason faults.py carries
    # its own table instead of reusing zlib.crc32
    assert crc32c(b"123456789") != zlib.crc32(b"123456789")


@given(st.lists(st.lists(st.integers(0, 255), max_size=40),
                min_size=0, max_size=8))
@settings(max_examples=30, deadline=None)
def test_crc32c_rows_matches_scalar(rows):
    """Property: the vectorized row CRC equals the scalar oracle per row,
    regardless of padding (ragged lengths, zero-length rows)."""
    msgs = [bytes(r) for r in rows]
    width = max([len(m) for m in msgs], default=0) or 1
    mat = np.zeros((len(msgs), width), np.uint8)
    for i, m in enumerate(msgs):
        mat[i, :len(m)] = np.frombuffer(m, np.uint8)
    lens = np.array([len(m) for m in msgs], np.int64)
    got = crc32c_rows(mat, lens)
    assert [int(x) for x in got] == [crc32c(m) for m in msgs]


# ================================================== WAL frame integrity

def _frame_off(i, vlen):
    return i * (FRAME_OVERHEAD + vlen)


@pytest.mark.parametrize("garbage_vlen", [0x7FFFFFFF, 13])
def test_wal_replay_stops_at_corrupt_length(garbage_vlen):
    """Satellite regression: a corrupt vlen field — whether it points past
    the buffer or re-frames onto garbage inside it — terminates replay at
    the last good frame instead of smuggling garbage records through."""
    wal, st_ = WriteAheadLog(), IOStats()
    vlen = 10
    for i in range(10):
        wal.append(1, i, i + 1, bytes([i]) * vlen, st_)
    wal.fsync(st_)
    off = _frame_off(5, vlen) + _CRC.size + _HDR.size - 4   # record 5's vlen
    wal._buf[off:off + 4] = struct.pack("<I", garbage_vlen)
    recs = list(wal.records())
    assert [r[1] for r in recs] == [0, 1, 2, 3, 4]
    # repair truncates to the last good frame; the log is then clean and
    # appendable — new records replay after the surviving prefix
    dropped = wal.repair()
    assert dropped == 5 * (FRAME_OVERHEAD + vlen)
    wal.append(1, 99, 100, b"zz", st_)
    recs = list(wal.records())
    assert [r[1] for r in recs] == [0, 1, 2, 3, 4, 99]
    assert recs[-1][3] == b"zz"


def test_wal_torn_payload_is_dropped():
    """A frame whose payload is cut off mid-value (torn write) is dropped;
    every complete preceding frame survives."""
    wal, st_ = WriteAheadLog(), IOStats()
    for i in range(4):
        wal.append(1, i, i + 1, b"x" * 20, st_)
    wal._buf = wal._buf[:-7]        # tear the last payload
    assert [r[1] for r in wal.records()] == [0, 1, 2]
    assert wal.repair() == (FRAME_OVERHEAD + 20) - 7


# ======================================= crash-point matrix (fail half)

def _run_script(db, ops, half=KEY_SPACE // 2):
    """The scripted workload: load → flush → (compact) → snapshot reads →
    migrate-roundtrip (strip [0, half) then re-import it).  Every step
    retries through one-shot injected faults; the roundtrip leaves the
    logical state unchanged, so the full-op oracle stays valid."""
    mid = len(ops) // 2
    for k, v in ops[:mid]:
        _retry(db.delete if v is None else db.put,
               *((k,) if v is None else (k, v)))
    _retry(db.flush)
    for k, v in ops[mid:]:
        _retry(db.delete if v is None else db.put,
               *((k,) if v is None else (k, v)))
    _retry(db.flush)
    snap = db.get_snapshot()
    try:
        for k in range(0, KEY_SPACE, 7):
            _retry(db.get, k, snap)
    finally:
        db.release_snapshot(snap)
    # migration roundtrip: copy out [0, half), strip it, re-import the copy
    # (the sharded facade's copy-then-strip order, collapsed onto one store)
    cols = db.export_range(0, half)
    try:
        db.strip_to_range(half, 1 << 64)
    except InjectedFault:
        return                        # donor unchanged: nothing to re-import
    if cols is not None:
        k, sq, vl, vv = cols
        run = build_run(k, sq, vl, vv, bits_per_key=db._bits_for_level(0),
                        drop_tombstones=True,
                        block_size=db.config.block_size,
                        key_bytes=db.config.key_bytes,
                        hash_fn=db._bloom_hash_fn())
        if len(run):
            _retry(db.import_migrated_run, run)


@pytest.mark.parametrize("site", FAULT_SITES)
def test_crash_matrix_one_shot_fault(site):
    """For every instrumented site: inject one failure into the scripted
    workload, retry through it, crash, recover — the recovered tree must
    equal the full-op oracle (retries mean nothing acknowledged is lost),
    and the store must still take writes."""
    ops = gen_ops(101, 400)
    f = FaultInjector(seed=5)
    f.fail(site, times=1)
    db = LSMStore(cfg(faults=f))
    _run_script(db, ops)
    assert f.fired.get(site) == 1, f"site {site} never fired"
    assert db_view(db) == oracle_view(ops, len(ops))
    db.crash()
    db.recover()
    assert db_view(db) == oracle_view(ops, len(ops))
    db.put(KEY_SPACE + 1, b"post-recovery")
    assert db.get(KEY_SPACE + 1) == b"post-recovery"


def test_chaos_probabilistic_faults_recover_to_oracle():
    """Seeded probabilistic faults across every foreground site at once;
    with per-op retry the acknowledged state still converges to the oracle
    and survives crash+recover."""
    ops = gen_ops(303, 500)
    f = FaultInjector(seed=9)
    for site in ("wal_append", "wal_fsync", "flush_write",
                 "manifest_fsync", "compaction_merge"):
        f.fail_prob(site, 0.05)
    db = LSMStore(cfg(faults=f))
    for k, v in ops:
        _retry(db.delete if v is None else db.put,
               *((k,) if v is None else (k, v)))
    f.clear()
    db.flush()
    assert db_view(db) == oracle_view(ops, len(ops))
    db.crash()
    db.recover()
    assert db_view(db) == oracle_view(ops, len(ops))


def test_wal_append_fault_excludes_the_op():
    """A failed wal_append raises *before* any mutation: the op is excluded
    (not half-applied), and a plain retry lands it."""
    f = FaultInjector()
    db = LSMStore(cfg(faults=f))
    apply_ops(db, gen_ops(1, 50))
    f.fail("wal_append")
    with pytest.raises(InjectedFault):
        db.put(999, b"x")
    assert db.get(999) is None
    db.put(999, b"x")                 # one-shot consumed: retry succeeds
    assert db.get(999) == b"x"
    f.fail("wal_append")
    with pytest.raises(InjectedFault):
        db.write_batch([(1000, b"y"), (1001, b"z")])


def test_manifest_fsync_fault_keeps_wal_for_replay():
    """The flush durability ordering: WAL release happens only after the
    manifest fsync, so a manifest fault costs nothing — crash+recover
    replays the still-fsynced WAL and every acknowledged op survives."""
    ops = gen_ops(17, 120)
    f = FaultInjector()
    db = LSMStore(cfg(faults=f, memtable_bytes=1 << 20))  # no auto-flush
    apply_ops(db, ops[:60])
    db.flush()
    apply_ops(db, ops[60:])
    f.fail("manifest_fsync")
    with pytest.raises(InjectedFault):
        db.flush()
    db.crash()
    db.recover()
    assert db_view(db) == oracle_view(ops, len(ops))


def test_wal_fsync_fault_then_crash_loses_only_unsynced_tail():
    ops = gen_ops(23, 120)
    f = FaultInjector()
    db = LSMStore(cfg(faults=f, memtable_bytes=1 << 20))
    apply_ops(db, ops[:60])
    db.flush()                        # ops[:60] durable
    apply_ops(db, ops[60:])
    f.fail("wal_fsync")
    with pytest.raises(InjectedFault):
        db.flush()
    db.crash()                        # the unsynced tail is gone
    db.recover()
    j = find_matching_prefix(db, ops)
    assert 60 <= j < len(ops) or db_view(db) == oracle_view(ops, len(ops))


# ================================================ corruption half

@pytest.mark.parametrize("mode", ["torn", "bitflip", "garbage"])
def test_wal_tail_corruption_is_prefix_consistent(mode):
    """Crash with a corrupted WAL tail: recovery checksums its way to the
    first bad frame and truncates — the recovered state is some prefix of
    the acknowledged ops, never a fabricated one."""
    ops = gen_ops(41, 200)
    f = FaultInjector(seed=7)
    tel = Telemetry()
    db = LSMStore(cfg(faults=f, telemetry=tel, memtable_bytes=1 << 20))
    apply_ops(db, ops[:100])
    db.flush()                        # durable floor: ops[:100]
    apply_ops(db, ops[100:160])
    db.fsync_wal()                    # synced region for bitflip/garbage
    apply_ops(db, ops[160:])          # unsynced tail
    f.corrupt_wal_tail(mode)
    db.crash()
    db.recover()
    assert f.fired.get("wal_tail:" + mode) == 1
    j = find_matching_prefix(db, ops)
    assert j >= 100, f"recovered state matches no acknowledged prefix ({j})"
    if mode == "torn":
        # torn writes only affect the unsynced tail: nothing synced is lost
        assert j >= 160
    else:
        # a damaged synced frame truncates replay before the watermark
        assert j < 160
        kinds = {e.kind: e.fields for e in tel.trace.dump()}
        assert "corruption" in kinds
    # the repaired log is clean: the next write/flush/recover round-trips
    db.put(KEY_SPACE + 2, b"after-repair")
    db.flush()
    db.crash()
    db.recover()
    assert db.get(KEY_SPACE + 2) == b"after-repair"


def test_manifest_corruption_falls_back_one_version():
    ops = gen_ops(53, 200)
    f = FaultInjector()
    tel = Telemetry()
    db = LSMStore(cfg(faults=f, telemetry=tel, memtable_bytes=1 << 20))
    apply_ops(db, ops[:100])
    db.flush()
    apply_ops(db, ops[100:])
    db.flush()
    f.corrupt_manifest_edit()
    db.crash()
    db.recover()
    assert f.fired.get("manifest_edit") == 1
    # the corrupt last edit was popped: the surviving version is an older
    # durable prefix (possibly the same logical state if the popped edit
    # was a compaction of it)
    j = find_matching_prefix(db, ops)
    assert j >= 100
    evs = [e for e in tel.trace.dump() if e.kind == "corruption"]
    assert any(e.fields.get("where") == "manifest" for e in evs)


def test_block_corruption_quarantined_never_silent():
    """A flipped byte in a run block: paranoid reads raise a typed
    CorruptionError naming the run and block, scrub reports it, and
    recovery refuses to serve it."""
    ops = [(k, bytes([k % 251]) * 40) for k in range(KEY_SPACE)]
    f = FaultInjector(seed=11)
    tel = Telemetry()
    db = LSMStore(cfg(faults=f, telemetry=tel, paranoid_checks=True,
                      memtable_bytes=1 << 20))
    apply_ops(db, ops)
    db.flush()
    assert db.scrub() and all(not r["bad_blocks"] for r in db.scrub())
    run = db._levels[0][-1] if db._levels[0] else \
        next(r for lvl in db._levels for r in lvl if len(r))
    bid = f.corrupt_run_block(run)
    victims = run.keys[run.block_of == bid]
    assert victims.size
    with pytest.raises(CorruptionError) as ei:
        db.get(int(victims[0]))
    assert ei.value.run_id == run.run_id and ei.value.block_id == bid
    with pytest.raises(CorruptionError):
        db.multi_get([int(k) for k in victims[:4]])
    # telemetry saw it, typed and located
    evs = [e for e in tel.trace.dump() if e.kind == "corruption"]
    assert any(e.fields.get("run_id") == run.run_id for e in evs)
    # scrub() reports without raising; recovery quarantines loudly
    report = db.scrub()
    bad = [r for r in report if r["bad_blocks"]]
    assert len(bad) == 1 and bad[0]["run_id"] == run.run_id \
        and bid in bad[0]["bad_blocks"]
    db.crash()
    with pytest.raises(CorruptionError) as ei:
        db.recover()
    assert ei.value.where == "recovery scrub"


def test_paranoid_reads_bit_identical_on_clean_store():
    """paranoid_checks only *verifies* — on a clean store every read lane
    returns byte-identical results with it on or off."""
    ops = gen_ops(67, 600)
    db = LSMStore(cfg())
    apply_ops(db, ops)
    db.flush()
    keys = list(range(KEY_SPACE))
    plain = (db.multi_get(keys), [db.get(k) for k in keys],
             db.scan(0, KEY_SPACE), [db.seek(k) for k in range(0, 300, 11)])
    db.config.paranoid_checks = True
    checked = (db.multi_get(keys), [db.get(k) for k in keys],
               db.scan(0, KEY_SPACE), [db.seek(k) for k in range(0, 300, 11)])
    assert plain == checked


# ================================== graceful degradation (retry → degrade)

@pytest.mark.parametrize("site", ["flush_write", "compaction_merge"])
def test_bg_transient_fault_retried_to_sync_oracle(site):
    """One background failure is absorbed by the retry stage: the quiesced
    tree is bit-identical to the fault-free synchronous oracle's."""
    ops = gen_ops(55, 1000)
    f = FaultInjector()
    f.fail(site, times=1)
    db_a = LSMStore(cfg(async_compaction=True, faults=f, bg_max_retries=3))
    db_s = LSMStore(cfg())
    try:
        apply_ops(db_a, ops)
        apply_ops(db_s, ops)
        db_a.flush()
        db_s.flush()
        assert db_a.wait_for_quiesce(60)
        assert f.fired.get(site) == 1
        assert db_a.stats.bg_retries >= 1
        assert db_a.stats.bg_gave_up == 0
        assert not db_a.degraded
        assert levels_bit_equal(db_a._levels, db_s._levels)
        keys = list(range(KEY_SPACE))
        assert db_a.multi_get(keys) == db_s.multi_get(keys)
    finally:
        db_a.close()


def test_bg_persistent_fault_degrades_read_only_then_recovers():
    """Retry budget exhausted: the store flips read-only degraded — writes
    raise StoreDegradedError, reads keep serving — and crash()+recover()
    restores write service with every fsynced byte intact."""
    ops = gen_ops(71, 2000, del_frac=0.0)
    f = FaultInjector().fail("flush_write", times=-1)
    tel = Telemetry()
    db = LSMStore(cfg(async_compaction=True, faults=f, bg_max_retries=1,
                      telemetry=tel))
    applied = []
    try:
        for k, v in ops:
            try:
                db.put(k, v)
                applied.append((k, v))
            except StoreDegradedError:
                break
        else:
            db.flush()
            with pytest.raises(RuntimeError, match="background"):
                db.wait_for_quiesce(60)
        assert db.degraded
        with pytest.raises(StoreDegradedError):
            db.put(0, b"rejected")
        with pytest.raises(StoreDegradedError):
            db.write_batch([(0, b"rejected")])
        db.get(applied[0][0])            # reads keep serving
        st = db.stats
        assert st.bg_retries >= 1 and st.bg_gave_up >= 1
        kinds = {e.kind for e in tel.trace.dump()}
        assert {"bg_retry", "bg_failure", "degraded"} <= kinds
        # operator action: clear the fault, crash + recover
        f.clear("flush_write")
        db.crash()
        db.recover()
        assert not db.degraded
        j = find_matching_prefix(db, applied)
        assert j >= 0, "recovery fabricated state after degradation"
        db.put(7, b"write service restored")
        assert db.get(7) == b"write service restored"
        db.flush()
        assert db.wait_for_quiesce(60)
    finally:
        db.close()


def test_degraded_close_is_idempotent_and_loss_free():
    """Satellite: close() racing a background failure — the first close is
    loud, every later close is a silent no-op, and no acknowledged write is
    lost (the sync path serves them all afterwards)."""
    ops = gen_ops(83, 600, del_frac=0.0)
    f = FaultInjector().fail("flush_write", times=-1)
    db = LSMStore(cfg(async_compaction=True, faults=f, bg_max_retries=0))
    applied = []
    for k, v in ops:
        try:
            db.put(k, v)
            applied.append((k, v))
        except StoreDegradedError:
            break
    with pytest.raises(RuntimeError, match="background"):
        db.close()
    db.close()                           # idempotent: no second raise
    db.close()
    assert db._scheduler is None
    # loss-free: every acknowledged write is readable on the sync path
    assert db_view(db) == oracle_view(applied, len(applied))
    # and the sync path takes writes again (no pipeline => no degradation)
    f.clear()
    db.put(KEY_SPACE + 3, b"sync path")
    db.flush()
    assert db.get(KEY_SPACE + 3) == b"sync path"


def test_rotate_losing_degradation_race_accepts_the_write():
    """Deterministic replay of the submit/degradation race: the worker
    publishes the pipeline failure in the instant between a writer passing
    the _degraded check and its rotation reaching submit().  The racing
    write must be ACCEPTED — its rotated segment is already fsynced and
    readable — never surfaced as the scheduler's plain RuntimeError; the
    next write gets the typed StoreDegradedError, and close() stays loud
    once, idempotent, and loss-free."""
    db = LSMStore(cfg(async_compaction=True))
    sched = db._scheduler
    real_submit = sched.submit
    boom = RuntimeError("simulated background job failure")

    def racing_submit(job):
        # what the worker's give-up path does, interleaved at the worst
        # possible instant: degraded flag first, then the failure submit()
        # checks — so the rotation in flight sees a dead pipeline
        db._enter_degraded(boom)
        with sched._cv:
            if sched._failure is None:
                sched._failure = boom
        return real_submit(job)

    sched.submit = racing_submit
    applied = []
    i = 0
    while not db.degraded:
        v = bytes([97 + i % 26]) * 50
        db.put(i % KEY_SPACE, v)      # the rotating put must not raise
        applied.append((i % KEY_SPACE, v))
        i += 1
        assert i < 10_000, "memtable never rotated"
    sched.submit = real_submit
    with pytest.raises(StoreDegradedError):
        db.put(0, b"rejected")        # typed, before any mutation
    with pytest.raises(RuntimeError, match="background"):
        db.close()                    # loud exactly once
    db.close()                        # then idempotent
    assert db._scheduler is None
    # loss-free: every acknowledged write (racing one included) serves
    assert db_view(db) == oracle_view(applied, len(applied))


def test_sharded_degradation_is_per_shard():
    """The facade degrades shard-by-shard: a dead pipeline in one shard
    rejects only that shard's writes while siblings keep full service."""
    f = FaultInjector().fail("flush_write", times=-1)
    db = make_store(cfg(shards=2, async_compaction=True, faults=f,
                        bg_max_retries=0))
    try:
        for i in range(4000):            # all keys < 2^63 → shard 0
            try:
                db.put(i % 100, b"v" * 50)
            except StoreDegradedError:
                break
        else:
            db.flush()
            try:
                db.wait_for_quiesce(30)
            except RuntimeError:
                pass
        assert db.degraded
        assert db.degraded_shards() == [0]
        f.clear()
        with pytest.raises(StoreDegradedError):
            db.put(5, b"rejected")       # shard 0: read-only
        db.get(5)                        # reads still serve
        big = (1 << 63) + 5
        db.put(big, b"sibling ok")       # shard 1: full service
        assert db.get(big) == b"sibling ok"
        db.crash()
        db.recover()
        assert db.degraded_shards() == []
        db.put(5, b"restored")
        assert db.get(5) == b"restored"
        db.flush()
        assert db.wait_for_quiesce(30)
        report = db.scrub()
        assert report and all("shard" in r and not r["bad_blocks"]
                              for r in report)
    finally:
        try:
            db.close()
        except RuntimeError:
            pass


# ======================================= recovery under telemetry (satellite)

def test_recover_with_telemetry_matches_plain_twin():
    ops = gen_ops(91, 400)
    tel = Telemetry()
    db_t = LSMStore(cfg(telemetry=tel, memtable_bytes=1 << 14))
    db_p = LSMStore(cfg(memtable_bytes=1 << 14))
    for db in (db_t, db_p):
        apply_ops(db, ops[:300])
        db.flush()
        apply_ops(db, ops[300:])
        db.fsync_wal()
        db.crash()
        db.recover()
    assert levels_bit_equal(db_t._levels, db_p._levels)
    assert db_t.memtable._data == db_p.memtable._data
    assert db_view(db_t) == db_view(db_p)
    kinds = {e.kind for e in tel.trace.dump()}
    assert "wal_replay" in kinds and "scrub" in kinds
    replay = [e for e in tel.trace.dump() if e.kind == "wal_replay"][-1]
    assert replay.fields["records"] >= 0
    assert tel.histogram("scrub").n >= 1
