"""Sharding rules: divisibility fallbacks, greedy spec dedup, cell coverage."""
import jax
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.configs import ARCH_IDS, SHAPES, get_config, shape_applicable
from repro.launch.sharding import make_rules, spec_for, tree_shardings
from repro.models.params import logical_specs, param_table


class FakeMesh:
    """Shape-only stand-in (tests must not allocate 256 devices)."""

    def __init__(self, shape, names):
        self.axis_names = names
        self.devices = np.zeros(shape)
        self.shape = dict(zip(names, shape))


MESH = FakeMesh((16, 16), ("data", "model"))


def test_spec_dedup_never_reuses_axis():
    rules = {"a": "model", "b": "model", "c": ("data",)}
    spec = spec_for(("a", "b", "c"), rules)
    assert spec == P("model", None, "data")


def test_heads_fallback_smollm():
    cfg = get_config("smollm_135m")  # 9 heads
    prules, arules = make_rules(cfg, MESH, "train", 256, 4096)
    assert prules["heads"] is None and prules["mlp"] == "model"
    assert arules["seq"] == "model"  # SP fallback engaged


def test_heads_tp_qwen3():
    cfg = get_config("qwen3_4b")  # 32 heads
    prules, arules = make_rules(cfg, MESH, "train", 256, 4096)
    assert prules["heads"] == "model"
    assert arules["seq"] is None
    # decode: kv=8 unshardable => flash-decoding over kv_seq
    _, drules = make_rules(cfg, MESH, "decode", 128, 32768)
    assert drules["kv_seq"] == "model" and drules["heads"] is None


def test_moe_expert_rules():
    g = get_config("granite_moe_1b_a400m")   # 32 experts: EP
    m = get_config("mixtral_8x22b")          # 8 experts: fallback to TP
    assert make_rules(g, MESH, "train", 256, 4096)[0]["expert"] == "model"
    assert make_rules(m, MESH, "train", 256, 4096)[0]["expert"] is None


def test_batch1_cells_replicate_batch():
    cfg = get_config("gemma3_1b")
    _, arules = make_rules(cfg, MESH, "decode", 1, 524288)
    assert arules["batch"] is None
    assert arules["kv_seq"] == ("data", "model")


def test_every_cell_has_valid_param_specs():
    """Every runnable (arch x shape): all param specs rank-match and every
    sharded dim is divisible by its mesh axes."""
    for arch in ARCH_IDS:
        cfg = get_config(arch)
        specs = logical_specs(cfg)
        flat = jax.tree.flatten(specs,
                                is_leaf=lambda x: isinstance(x, tuple))[0]
        table = jax.tree.flatten(param_table(cfg),
                                 is_leaf=lambda x: hasattr(x, "logical"))[0]
        for shape_name, shape in SHAPES.items():
            if not shape_applicable(cfg, shape)[0]:
                continue
            prules, _ = make_rules(cfg, MESH, shape.mode,
                                   shape.global_batch, shape.seq_len)
            for spec_leaf, tbl in zip(flat, table):
                p = spec_for(spec_leaf, prules)
                assert len(p) <= len(tbl.shape)
                for dim, part in zip(tbl.shape, tuple(p)):
                    if part is None:
                        continue
                    parts = (part,) if isinstance(part, str) else part
                    k = int(np.prod([MESH.shape[a] for a in parts]))
                    assert dim % k == 0, (arch, tbl.shape, p)
